"""Chunked SSD scan (Mamba2 state-space duality) — pure JAX.

HFAV framing (DESIGN.md §5): the per-chunk algorithm is the engine's
storage contraction applied to the SSM state — the (N, P) state carried
between chunks is a rolling buffer with reuse distance one chunk, and the
intra/inter-chunk split is the prologue/steady/epilogue phase structure.
Within a chunk everything is dense matmuls (MXU-friendly); the chunk loop
is a ``lax.scan`` (differentiable; the training path runs inside rematted
blocks).  The Pallas version (kernel.py) keeps the state in VMEM scratch.

Cumulative sums are computed with a lower-triangular ones matmul — the
MXU-idiomatic prefix sum used in TPU SSD implementations.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("chunk", "unroll"))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk: int = 128, unroll: bool = False):
    """x (B,S,H,P), dt (B,S,H) post-softplus, A (H,) negative,
    Bm/Cm (B,S,N), D (H,) -> y (B,S,H,P)."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, "pad sequence to the chunk size"
    nc = S // L

    f32 = jnp.float32
    xc = jnp.moveaxis(x.reshape(Bsz, nc, L, H, P), 1, 0).astype(f32)
    dtc = jnp.moveaxis(dt.reshape(Bsz, nc, L, H), 1, 0).astype(f32)
    bc = jnp.moveaxis(Bm.reshape(Bsz, nc, L, N), 1, 0).astype(f32)
    cc = jnp.moveaxis(Cm.reshape(Bsz, nc, L, N), 1, 0).astype(f32)
    A = A.astype(f32)

    tril = jnp.tril(jnp.ones((L, L), f32))  # inclusive prefix-sum operator
    tril_strict = jnp.tril(jnp.ones((L, L), f32), k=-1)

    def step(state, inp):  # state (B,H,N,P)
        xi, dti, bi, ci = inp
        cs = jnp.einsum("ts,bsh->bth", tril, dti)  # inclusive cumsum (B,L,H)
        # decay from chunk entry to t (inclusive of a_t)
        din = jnp.exp(A[None, None, :] * cs)  # (B,L,H)
        # pairwise decay exp(A (cs_t - cs_tau)) for tau <= t
        seg = cs[:, :, None, :] - cs[:, None, :, :]  # (B,L,L,H)
        decay = jnp.exp(A[None, None, None, :] * seg)
        mask = tril[None, :, :, None] > 0
        decay = jnp.where(mask, decay, 0.0)
        # intra-chunk: M[t,tau] = (C_t . B_tau) decay dt_tau
        cb = jnp.einsum("btn,bsn->bts", ci, bi)  # (B,L,L)
        M = cb[:, :, :, None] * decay * dti[:, None, :, :]  # (B,L,L,H)
        y = jnp.einsum("btsh,bshp->bthp", M, xi)
        # inter-chunk: C_t . (decay_to_t * S_prev)
        y = y + jnp.einsum("btn,bhnp->bthp", ci, state) * din[..., None]
        # state passing: S' = decay_full * S + B^T diag(w) X
        w = jnp.exp(A[None, None, :] * (cs[:, -1:, :] - cs)) * dti  # (B,L,H)
        z = jnp.einsum("bsn,bsh,bshp->bhnp", bi, w, xi)
        dfull = jnp.exp(A[None, :] * cs[:, -1, :])  # (B,H)
        state = dfull[..., None, None] * state + z
        return state, y

    s0 = jnp.zeros((Bsz, H, N, P), f32)
    _, ys = jax.lax.scan(step, s0, (xc, dtc, bc, cc), unroll=unroll)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S, H, P)
    y = y + (D[None, None, :, None] * x.astype(f32))
    return y.astype(x.dtype)


def ssd(x, dt, A, Bm, Cm, D, *, chunk: int = 128, impl: str = "chunked",
        unroll: bool = False, interpret: bool = True):
    if impl == "reference":
        from .ref import naive_ssd
        return naive_ssd(x, dt, A, Bm, Cm, D)
    if impl == "chunked":
        return ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk, unroll=unroll)
    if impl == "pallas":
        from .kernel import ssd_pallas
        return ssd_pallas(x, dt, A, Bm, Cm, D, chunk=chunk, interpret=interpret)
    raise ValueError(f"unknown ssd impl {impl!r}")
