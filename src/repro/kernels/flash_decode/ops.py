"""Decode-attention front door: pallas kernel or chunked-scan fallback."""
from __future__ import annotations

from ..flash_attention.ops import chunked_attention
from .kernel import flash_decode
from .ref import dense_decode


def decode_attention(q, k_cache, v_cache, lengths, *, window=None,
                     scale=None, impl: str = "chunked", chunk: int = 512,
                     unroll: bool = False, interpret: bool = True):
    """q: (B, H, D) one token per sequence; caches (B, S, KVH, D)."""
    if impl == "reference":
        return dense_decode(q, k_cache, v_cache, lengths, window=window, scale=scale)
    if impl == "chunked":
        out = chunked_attention(
            q[:, None], k_cache, v_cache,
            kv_len=lengths, qpos=(lengths - 1)[:, None],
            window=window, scale=scale, chunk=chunk, unroll=unroll,
        )
        return out[:, 0]
    if impl == "pallas":
        return flash_decode(q, k_cache, v_cache, lengths, window=window,
                            scale=scale, interpret=interpret)
    raise ValueError(f"unknown decode impl {impl!r}")
