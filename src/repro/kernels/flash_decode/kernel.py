"""Split-KV decode attention as a Pallas TPU kernel.

One new token per sequence attends over a long KV cache.  HFAV framing:
the KV axis is the reduced dimension of a reduction triple — identity
init at the first KV block, online-softmax combine across blocks
(rolling (m, l, acc) accumulators in VMEM), normalize in the epilogue.
Per-sequence cache lengths arrive via scalar prefetch (SMEM) and mask the
tail block; the sliding-window variant masks the head blocks.

Grid = (B, KVH, nkv); the q block carries the ``group`` query heads that
share one KV head (GQA), giving an (group, C) score tile per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # scalar prefetch: (B,) int32 cache lengths
    q_ref, k_ref, v_ref, o_ref,
    acc_ref, m_ref, l_ref,
    *,
    bkv: int,
    nkv: int,
    window: int | None,
    scale: float,
):
    b = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale  # (group, D)
    k = k_ref[0, 0].astype(jnp.float32)  # (C, D)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (group, C)

    length = len_ref[b]
    kpos = ki * bkv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < length
    if window is not None:
        mask &= kpos > (length - 1) - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.maximum(m_prev, s.max(axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v_ref[0, 0].astype(jnp.float32), (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_cur

    @pl.when(ki == nkv - 1)
    def _fini():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_decode(
    q: jnp.ndarray,  # (B, H, D) — one token per sequence
    k_cache: jnp.ndarray,  # (B, S, KVH, D)
    v_cache: jnp.ndarray,
    lengths: jnp.ndarray,  # (B,) int32 valid cache lengths (inclusive of new token)
    *,
    window: int | None = None,
    scale: float | None = None,
    block_kv: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, D = q.shape
    _, S, KVH, _ = k_cache.shape
    group = H // KVH
    scale = scale if scale is not None else D ** -0.5
    bkv = min(block_kv, S)
    while bkv > 1 and S % bkv:
        bkv //= 2
    assert S % bkv == 0, "pad the cache to the KV block size"
    nkv = S // bkv

    qv = q.reshape(B, KVH, group, D)
    kv = k_cache.transpose(0, 2, 1, 3)  # (B, KVH, S, D)
    vv = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _decode_kernel, bkv=bkv, nkv=nkv, window=window, scale=scale
    )
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, KVH, nkv),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, ki, lens: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, ki, lens: (b, h, ki, 0)),
            pl.BlockSpec((1, 1, bkv, D), lambda b, h, ki, lens: (b, h, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, ki, lens: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, D), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KVH, group, D), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qv, kv, vv)
    return out.reshape(B, H, D)
