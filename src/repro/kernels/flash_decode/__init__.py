from .ops import decode_attention
from .kernel import flash_decode
from .ref import dense_decode
