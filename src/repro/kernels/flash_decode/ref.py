"""Oracle: dense decode attention over the cache with length masking."""
from __future__ import annotations

from ..flash_attention.ref import dense_attention


def dense_decode(q, k_cache, v_cache, lengths, *, window=None, scale=None):
    # q: (B, H, D) -> (B, 1, H, D); qpos = lengths - 1
    out = dense_attention(
        q[:, None], k_cache, v_cache,
        kv_len=lengths, qpos=(lengths - 1)[:, None],
        window=window, scale=scale,
    )
    return out[:, 0]
