"""Roofline terms from compiled dry-run artifacts (assignment §ROOFLINE).

TPU v5e constants: 197 TFLOP/s bf16 per chip, 819 GB/s HBM, ~50 GB/s/link
ICI.  ``compiled.cost_analysis()`` reports FLOPs/bytes for the *per-device*
partitioned module; we scale by chip count so the three terms match the
assignment's global formulas (numerically identical to per-device /
per-chip-peak).  Collective bytes are parsed from the optimized HLO —
operand sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (async ``-start`` forms counted once).
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass, field

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_BW = 50e9  # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([0-9,]+)\}")


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(1, int(m.group(2)))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return max(1, len(m.group(1).split(",")))
    return default


def collective_bytes(hlo_text: str, default_group: int = 1) -> dict[str, float]:
    """Per-device *link traffic* bytes of each collective in optimized HLO.

    XLA prints operands untyped in compact HLO, so we read the result
    shapes (LHS) and apply ring-algorithm traffic conventions with the
    parsed replica-group size g:

        all-gather         result * (g-1)/g
        all-reduce         2 * result * (g-1)/g
        reduce-scatter     result * (g-1)        (operand = g * result)
        all-to-all         result * (g-1)/g
        collective-permute result

    Async ``-start`` forms count once; ``-done`` never."""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "=" not in stripped:
            continue
        for c in _COLLECTIVES:
            hit = None
            for tok in (f" {c}(", f" {c}-start("):
                if tok in stripped:
                    hit = tok
                    break
            if hit is None:
                continue
            lhs = stripped.split(hit, 1)[0]
            # result shapes appear after '=' on the LHS
            result = _shape_bytes(lhs.split("=", 1)[1])
            g = _group_size(stripped, default_group)
            if c == "all-gather":
                out[c] += result * (g - 1) / g
            elif c == "all-reduce":
                out[c] += 2.0 * result * (g - 1) / g
            elif c == "reduce-scatter":
                out[c] += result * (g - 1)
            elif c == "all-to-all":
                out[c] += result * (g - 1) / g
            else:  # collective-permute
                out[c] += result
            break
    return out


def extrapolate(cost1: dict, cost2: dict, units: int) -> dict:
    """Linear per-layer-unit extrapolation: total(u) = c1 + (u-1)*(c2-c1).
    Applied to flops / bytes / per-collective traffic from the unrolled
    1-unit and 2-unit analysis compiles."""
    out = {}
    for k in cost1:
        c1 = float(cost1.get(k, 0.0))
        c2 = float(cost2.get(k, c1))
        # clamp below at the 1-unit cost: tiny models can show c2 < c1
        # from compile-to-compile CSE noise, and a total below one layer's
        # cost is definitionally impossible
        out[k] = max(c1 + (units - 1) * (c2 - c1), c1)
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0  # 6*N*D (or 6*N_active*D)
    memory_stats: dict = field(default_factory=dict)

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        total = self.flops_per_device * self.n_chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the dominant-term bound spent on useful model flops:
        (model_flops / chips / peak) / max(term)."""
        t_use = self.model_flops / self.n_chips / PEAK_FLOPS
        t_dom = max(self.t_compute, self.t_memory, self.t_collective)
        return t_use / t_dom if t_dom else 0.0

    def to_dict(self) -> dict:
        d = asdict(self)
        d.update(
            t_compute=self.t_compute,
            t_memory=self.t_memory,
            t_collective=self.t_collective,
            bottleneck=self.bottleneck,
            useful_flops_ratio=self.useful_flops_ratio,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def model_flops_for(cfg, shape, *, active: bool = True) -> float:
    """6*N*D for train (fwd+bwd), 2*N*D for inference-ish steps."""
    n = cfg.n_active_params() if active else cfg.n_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def analyze(arch: str, shape_name: str, mesh_name: str, n_chips: int,
            cost: dict, hlo_text: str, memory_stats: dict, cfg, shape) -> Roofline:
    coll = collective_bytes(hlo_text)
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, n_chips=n_chips,
        flops_per_device=float(cost.get("flops", 0.0)),
        bytes_per_device=float(cost.get("bytes accessed", 0.0)),
        coll_bytes_per_device=float(sum(coll.values())),
        coll_breakdown=coll,
        model_flops=model_flops_for(cfg, shape),
        memory_stats=memory_stats,
    )
