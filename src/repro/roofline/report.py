"""Render the dry-run JSON records into the EXPERIMENTS.md tables."""
from __future__ import annotations

import glob
import json
import os


def load_records(out_dir: str = "reports/dryrun") -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def _fmt_t(x: float) -> str:
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def roofline_table(recs: list[dict], mesh: str = "16x16") -> str:
    head = ("| arch | shape | t_compute | t_memory | t_collective | bottleneck "
            "| MODEL_FLOPS/HLO | roofline frac |\n"
            "|---|---|---|---|---|---|---|---|")
    rows = [head]
    for r in recs:
        if r.get("mesh") != mesh:
            continue
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"skipped | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                        f"ERROR | — | — |")
            continue
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_t(r['t_compute'])} "
            f"| {_fmt_t(r['t_memory'])} | {_fmt_t(r['t_collective'])} "
            f"| **{r['bottleneck']}** | {r['useful_flops_ratio']:.3f} "
            f"| {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(rows)


def dryrun_table(recs: list[dict]) -> str:
    head = ("| arch | shape | mesh | status | bytes/dev (args+temp) | "
            "HLO GFLOPs/dev | coll GB/dev |\n|---|---|---|---|---|---|---|")
    rows = [head]
    for r in recs:
        if r.get("status") == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"skipped ({r['reason'][:40]}…) | — | — | — |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"ERROR: {r.get('error','')[:60]} | — | — | — |")
            continue
        ms = r.get("memory_stats", {})
        byt = (ms.get("argument_size_in_bytes", 0)
               + ms.get("temp_size_in_bytes", 0))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok "
            f"| {byt/1e9:.2f} GB | {r['flops_per_device']/1e9:.1f} "
            f"| {r['coll_bytes_per_device']/1e9:.2f} |"
        )
    return "\n".join(rows)


def main() -> None:
    recs = load_records()
    print("## Single-pod (16x16) roofline\n")
    print(roofline_table(recs, "16x16"))
    print("\n## Multi-pod (2x16x16) roofline\n")
    print(roofline_table(recs, "2x16x16"))
    print("\n## Dry-run memory/cost records\n")
    print(dryrun_table(recs))


if __name__ == "__main__":
    main()
