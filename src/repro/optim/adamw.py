"""AdamW with decoupled weight decay, global-norm clipping, cosine
schedule, and optional gradient compression around the data-parallel
all-reduce.  Optimizer state shards exactly like the parameters
(ZeRO: m/v carry the same PartitionSpecs)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWCfg:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_ratio: float = 0.1
    # 'none' | 'bf16': compress gradients before the DP all-reduce.
    grad_compression: str = "none"


def init_opt_state(params):
    return {
        "m": jax.tree.map(jnp.zeros_like, params),
        "v": jax.tree.map(jnp.zeros_like, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_at(cfg: AdamWCfg, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def compress_grads(grads, mode: str):
    """Gradient compression hook.  'bf16' halves all-reduce bytes; the
    decompress is a cast back (error feedback unnecessary at bf16 for
    Adam-class optimizers)."""
    if mode == "bf16":
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
    return grads


def adamw_update(cfg: AdamWCfg, params, grads, state):
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    b1, b2 = cfg.b1, cfg.b2

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** step.astype(jnp.float32))
        vhat = v / (1 - b2 ** step.astype(jnp.float32))
        p_new = p - lr * (mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p)
        return p_new, m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"lr": lr, "grad_norm": gnorm}
