#!/usr/bin/env python
"""Print the cross-PR benchmark trajectory from ``BENCH_<pr>.json``.

Every PR's ``scripts/bench.sh`` run leaves a ``BENCH_<pr>.json`` record
at the repo root (the ``benchmarks.lifted --json`` output).  This tool
lines those records up into one table per suite section so the
trajectory — wall time per leg, throughput, interpreter overhead,
plan-cache speedup, and (from PR 8 on) the vectorization analyzer's
predicted redundant-load ratio — is readable at a glance.  From PR 9
the interpreters table carries ``*_layout`` legs (the LayoutApply
pass on) whose ``vec`` column is the *post-transform* prediction, so
predicted ratio drops sit beside the measured throughput delta::

    python scripts/bench_trend.py                # all BENCH_*.json
    python scripts/bench_trend.py BENCH_6.json BENCH_8.json
    python scripts/bench_trend.py --metric mcells_per_s

Cells print ``-`` where a record predates the leg or the field.  The
``vec`` column comes from the newest record carrying the analyzer's
summary, so model predictions sit beside every measured trend row.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent


def discover() -> list[pathlib.Path]:
    """All ``BENCH_<n>.json`` at the repo root, ordered by PR number."""
    found = []
    for p in ROOT.glob("BENCH_*.json"):
        m = re.fullmatch(r"BENCH_(\d+)\.json", p.name)
        if m:
            found.append((int(m.group(1)), p))
    return [p for _, p in sorted(found)]


def load(paths) -> list[tuple[str, dict]]:
    records = []
    for p in paths:
        p = pathlib.Path(p)
        label = re.sub(r"^BENCH_(\d+)\.json$", r"PR\1", p.name)
        records.append((label, json.loads(p.read_text())))
    return records


def _fmt(val, nd=1):
    if val is None:
        return "-"
    if isinstance(val, float):
        return f"{val:.{nd}f}"
    return str(val)


def _table(title, rows, headers):
    """Render one aligned text table (headers + rows of strings)."""
    widths = [max(len(h), *(len(r[i]) for r in rows)) if rows else len(h)
              for i, h in enumerate(headers)]
    print(f"== {title} ==")
    print("  " + "  ".join(h.ljust(w) for h, w in zip(headers, widths)))
    for r in rows:
        print("  " + "  ".join(c.rjust(w) if i else c.ljust(w)
                               for i, (c, w) in enumerate(zip(r, widths))))
    print()


def _leg_names(records, section):
    """Union of leg names across records, in order of first appearance."""
    names: list[str] = []
    for _, rec in records:
        for leg in rec.get(section, ()):
            if leg["name"] not in names:
                names.append(leg["name"])
    return names


def _cell(rec, section, name, metric, nd):
    by_name = {leg["name"]: leg for leg in rec.get(section, ())}
    leg = by_name.get(name)
    return _fmt(leg.get(metric) if leg else None, nd)


def trend(records, section, metric, nd=1, extra=None):
    """Rows: one per leg, one metric column per record.

    ``extra`` adds trailing columns filled from the newest record that
    carries the field: one ``(header, field, nd)`` tuple, or a list of
    them (the serving table carries p50/p99 beside requests/s)."""
    names = _leg_names(records, section)
    if not names:
        return
    extras = ([extra] if isinstance(extra, tuple) else list(extra or ()))
    headers = ["leg"] + [label for label, _ in records]
    rows = []
    for name in names:
        row = [name] + [_cell(rec, section, name, metric, nd)
                        for _, rec in records]
        for _, field, xnd in extras:
            val = None
            for _, rec in reversed(records):
                leg = {g["name"]: g for g in rec.get(section, ())}.get(name)
                if leg and leg.get(field) is not None:
                    val = leg[field]
                    break
            row.append(_fmt(val, xnd))
        rows.append(row)
    headers.extend(xh for xh, _, _ in extras)
    _table(f"{section}: {metric}", rows, headers)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Cross-PR benchmark trajectory from BENCH_<pr>.json "
                    "records.")
    ap.add_argument("records", nargs="*",
                    help="BENCH_<pr>.json files (default: every one at "
                         "the repo root, ordered by PR number)")
    ap.add_argument("--metric", default="us_per_call",
                    choices=("us_per_call", "mcells_per_s"),
                    help="which lifted-leg metric to tabulate "
                         "(default: us_per_call)")
    args = ap.parse_args(argv)

    paths = args.records or discover()
    if not paths:
        print("bench_trend: no BENCH_<pr>.json records found",
              file=sys.stderr)
        return 1
    records = load(paths)

    trend(records, "legs", args.metric, nd=1,
          extra=("vec_ratio", "vec_redundant_load_ratio", 2))
    # the predicted-vs-measured juxtaposition: the analyzer's
    # redundant-load ratio (post-transform on *_layout legs) beside
    # every interpreter leg's measured trend
    trend(records, "interpreters", args.metric, nd=1,
          extra=("vec_ratio", "vec_redundant_load_ratio", 2))
    trend(records, "plan_cache", "speedup", nd=1)
    # serving throughput (PR 10 on): requests/s per leg, with the
    # newest record's latency percentiles beside the trend
    trend(records, "serving", "requests_per_s", nd=1,
          extra=[("p50_ms", "p50_ms", 2), ("p99_ms", "p99_ms", 2)])
    return 0


if __name__ == "__main__":
    sys.exit(main())
