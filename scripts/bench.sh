#!/usr/bin/env bash
# Benchmark trajectory recorder: run the lifted-restriction suite and
# the PlanServe load test, and write the merged BENCH_<pr>.json (per-leg
# wall time + backend + serving throughput) at the repo root, so every
# PR leaves a perf baseline the next one can regress against.
#
#   scripts/bench.sh [pr-number]
#
# Without an argument the PR number is inferred as one past the number
# of PR entries already recorded in CHANGES.md (i.e. "this PR").
# Off-TPU the legs run in interpret mode on bounded sizes; on a TPU
# runtime export BENCH_NO_INTERPRET=1 for real timings.
set -euo pipefail
cd "$(dirname "$0")/.."

PR="${1:-$(($(grep -c '^- PR' CHANGES.md) + 1))}"
FLAGS=(--json)
if [[ "${BENCH_NO_INTERPRET:-0}" == "1" ]]; then
    FLAGS+=(--no-interpret)
fi
LIFTED="$(mktemp)"
SERVE="$(mktemp)"
trap 'rm -f "$LIFTED" "$SERVE"' EXIT
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.lifted "${FLAGS[@]}" > "$LIFTED"
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m benchmarks.serve --json > "$SERVE"
python - "$LIFTED" "$SERVE" > "BENCH_${PR}.json" <<'PY'
import json
import sys

rec = json.load(open(sys.argv[1]))
rec["serving"] = json.load(open(sys.argv[2]))["serving"]
json.dump(rec, sys.stdout, indent=1)
print()
PY
echo "wrote BENCH_${PR}.json"
