#!/usr/bin/env bash
# Tier-1 fast wrapper: the full suite minus tests marked `slow`
# (currently the ~160s dryrun subprocess compile).  The docs guardrails
# (scripts/check_docs.sh) run inside the suite via tests/test_docs.py,
# so both this wrapper and the canonical tier-1 command in ROADMAP.md
# pick them up without a duplicate invocation.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "not slow" "$@"
