#!/usr/bin/env bash
# Tier-1 fast wrapper: the full suite minus tests marked `slow`
# (currently the ~160s dryrun subprocess compile).  The canonical
# tier-1 command in ROADMAP.md runs everything.
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -q -m "not slow" "$@"
