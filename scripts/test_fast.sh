#!/usr/bin/env bash
# Tier-1 fast wrapper: the full suite minus tests marked `slow`
# (currently the ~160s dryrun subprocess compile).  The docs guardrails
# (scripts/check_docs.sh) run inside the suite via tests/test_docs.py,
# so both this wrapper and the canonical tier-1 command in ROADMAP.md
# pick them up without a duplicate invocation.
#
# When pytest-cov is installed (requirements-dev.txt) a *full-suite*
# run also enforces a line-coverage floor over repro.core — the engine
# is the paper's contribution and must not grow untested branches.  The
# floor is a ratchet: raise it as coverage rises, never lower it to
# make a PR pass.  Subset invocations (`scripts/test_fast.sh
# tests/test_engine.py`) skip the gate — a partial run cannot meet a
# whole-suite floor.  (The container image may lack pytest-cov; the
# suite then runs without the coverage gate rather than failing on a
# missing dep.)
#
# The static gates (ruff, when installed, and the golden-plan lint —
# scripts/lint.sh) run first: a plan or lint regression fails fast,
# before the ~4-minute suite.
set -euo pipefail
cd "$(dirname "$0")/.."

scripts/lint.sh

# Conformance surface for this run: every registered plan interpreter
# is swept against the whole program corpus by
# tests/test_interp_conformance.py — make the matrix visible up front
# so a PR that (un)registers an interpreter shows its blast radius.
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'PY'
from repro.core.interpreters import get_interpreter, registered_interpreters
from repro.core.programs import ALL_PROGRAMS
interps = registered_interpreters()
aware = [n for n in interps if get_interpreter(n).layout_aware]
print(f"interpreter matrix: {len(interps)} interpreters "
      f"({', '.join(interps)}) x {len(ALL_PROGRAMS)} programs "
      f"x 2 streaming modes")
print(f"layout-aware matrix: {len(aware)} interpreters "
      f"({', '.join(aware) or 'none'}) additionally sweep the corpus "
      f"LayoutApply-transformed (tests/test_layoutapply.py)")
from repro.serve.plans import VMAP_SAFE
print(f"serving surface: PlanServe buckets/batcher over "
      f"{len(VMAP_SAFE)} vmap-safe backends "
      f"({', '.join(sorted(VMAP_SAFE))}) — tests/test_serve.py; "
      f"multi-process warm start is slow-marked "
      f"(tests/test_serve_workers.py, tier-1 only)")
PY

COV_ARGS=()
if [ "$#" -eq 0 ] && python -c "import pytest_cov" >/dev/null 2>&1; then
    COV_ARGS=(--cov=repro.core --cov-report=term --cov-fail-under=75)
fi
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -q -m "not slow" ${COV_ARGS[@]+"${COV_ARGS[@]}"} "$@"
