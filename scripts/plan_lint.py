#!/usr/bin/env python
"""Lint KernelPlans with the static analyzers (plancheck + vecscan).

Targets — freely mixed, any number of them::

    PYTHONPATH=src python scripts/plan_lint.py heat3d cosmo     # by name
    PYTHONPATH=src python scripts/plan_lint.py tests/goldens/plans
    PYTHONPATH=src python scripts/plan_lint.py .plan_cache/<key>.json

* a **program name** from ``repro.core.programs`` is planned through
  the analysis pipeline and the resulting plan is linted;
* a **file** is loaded as a serialized plan — both the bare golden
  form (``KernelPlan.to_dict``) and the plan-cache entry form (with
  its ``{"jax", "repro", "plan"}`` header) are accepted;
* a **directory** (a plan cache or the golden corpus) lints every
  ``*.json`` inside it;
* no targets at all lints the golden corpus plus every
  ``ALL_PROGRAMS`` entry.

A file that fails to load or validate is reported as ``PC000``.  With
``--sizes Nj=64,Ni=512`` the VMEM budget check (PC003) runs against
``--vmem-budget`` / ``REPRO_VMEM_BUDGET_BYTES``.  ``--vec``
additionally runs the vectorization analyzer
(:mod:`repro.core.vecscan`) and merges its ``PV`` diagnostics in.
``--format json`` emits one JSON object per analyzed plan (a JSON
line: target, diagnostics, and — under ``--vec`` — the
vector-efficiency summary) for CI and the autotuner to consume
without scraping text.  Exit status is non-zero iff any target
carries an **error**-severity finding (warnings alone exit 0; add
``--strict`` to fail on those too) — identical in both formats.

``--apply-layout auto|force`` runs every resolved plan through the
LayoutApply pass (:mod:`repro.core.layoutapply`) before linting, so
the analyzers see the transformed plan — this is how the lint.sh
gate checks that layout transformation never introduces analyzer
errors.  ``--update-vec-baseline`` regenerates
``tests/goldens/vec_lint_baseline.json`` from the golden corpus
(with the selected ``--apply-layout`` mode) instead of linting.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core.plan import KernelPlan  # noqa: E402
from repro.core.plancheck import (Diagnostic, check_plan,  # noqa: E402
                                  has_errors)

GOLDEN_DIR = ROOT / "tests" / "goldens" / "plans"


def load_plan_file(path: pathlib.Path) -> KernelPlan:
    """Deserialize one plan file, unwrapping a plan-cache header."""
    payload = json.loads(path.read_text())
    if "plan" in payload and "schema" not in payload:
        payload = payload["plan"]
    return KernelPlan.from_dict(payload)


def _resolve_plan(target: str):
    """One CLI target to ``(kplan, load-failure Diagnostic or None)``."""
    path = pathlib.Path(target)
    if path.is_dir():
        raise ValueError("directories are expanded by the caller")
    if path.exists():
        try:
            return load_plan_file(path), None
        except Exception as e:
            return None, Diagnostic(
                "PC000", "error", path.stem, "",
                f"plan failed to load: {type(e).__name__}: {e}")
    from repro.core.programs import ALL_PROGRAMS
    build = ALL_PROGRAMS.get(target)
    if build is None:
        return None, Diagnostic(
            "PC000", "error", target, "",
            f"no such file, directory, or program "
            f"(known programs: {', '.join(sorted(ALL_PROGRAMS))})")
    from repro.core import plan_pallas
    from repro.core.dataflow import build_dataflow
    from repro.core.fusion import fuse_inest_dag
    from repro.core.infer import infer
    from repro.core.reuse import analyze_storage
    idag = infer(build())
    return plan_pallas(
        analyze_storage(fuse_inest_dag(build_dataflow(idag))), idag), None


def lint_target(target: str, sizes, budget=None, *, vec: bool = False,
                apply_mode: str = "off"):
    """Resolve one CLI target to ``(label, diagnostics, vec summary)``.

    The vec summary (:meth:`repro.core.vecscan.VecReport.summary`) is
    ``None`` unless ``vec=True`` and the plan loaded.  With
    ``apply_mode`` other than ``"off"`` the plan is first run through
    :func:`repro.core.layoutapply.apply_layout`; a transformation
    failure is reported as ``PC000``."""
    kplan, failure = _resolve_plan(target)
    if failure is not None:
        return target, [failure], None
    if apply_mode != "off":
        from repro.core.layoutapply import apply_layout
        try:
            kplan = apply_layout(kplan, mode=apply_mode, sizes=sizes).plan
        except Exception as e:
            return target, [Diagnostic(
                "PC000", "error", target, "",
                f"layout apply ({apply_mode}) failed: "
                f"{type(e).__name__}: {e}")], None
    diags = check_plan(kplan, sizes=sizes, budget=budget)
    summary = None
    if vec and not has_errors(diags):
        from repro.core.vecscan import scan_plan
        rep = scan_plan(kplan, sizes=sizes)
        diags = list(diags) + list(rep.diagnostics)
        summary = rep.summary()
    return target, diags, summary


VEC_BASELINE = ROOT / "tests" / "goldens" / "vec_lint_baseline.json"


def update_vec_baseline(sizes, budget=None, *, apply_mode="off") -> int:
    """Regenerate the vec-lint baseline from the golden corpus.

    Lints every golden plan with ``--vec`` semantics (and the given
    LayoutApply mode — lint.sh gates with ``--apply-layout force``)
    and writes the per-plan error counts that the lint.sh gate
    compares against."""
    errors = {}
    for path in sorted(GOLDEN_DIR.glob("*.json")):
        _, diags, _ = lint_target(str(path), sizes, budget, vec=True,
                                  apply_mode=apply_mode)
        errors[path.name] = sum(d.severity == "error" for d in diags)
    payload = {
        "comment": "error-severity counts per golden plan from "
                   "plan_lint.py --vec --apply-layout force --format "
                   "json; the lint.sh gate fails on any increase; "
                   "regenerate with plan_lint.py --update-vec-baseline "
                   "--apply-layout force",
        "errors": errors,
    }
    VEC_BASELINE.write_text(
        json.dumps(payload, indent=1, sort_keys=True) + "\n")
    print(f"plan_lint: wrote {VEC_BASELINE.relative_to(ROOT)} "
          f"({len(errors)} plan(s), {sum(errors.values())} error(s), "
          f"apply_layout={apply_mode})")
    return 0


def parse_sizes(spec):
    """``"Nj=64,Ni=512"`` -> ``{"Nj": 64, "Ni": 512}`` (None stays None)."""
    if not spec:
        return None
    sizes = {}
    for part in spec.split(","):
        sym, _, val = part.partition("=")
        if not val:
            raise SystemExit(f"--sizes: expected SYM=INT, got {part!r}")
        sizes[sym.strip()] = int(val)
    return sizes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Lint KernelPlans (programs by name, serialized plan "
                    "files, or whole plan-cache/golden directories) with "
                    "the repro.core.plancheck static analyzer and, under "
                    "--vec, the repro.core.vecscan vectorization "
                    "analyzer.")
    ap.add_argument("targets", nargs="*",
                    help="program names, plan files, or directories "
                         "(default: the golden corpus + ALL_PROGRAMS)")
    ap.add_argument("--sizes", default=None, metavar="Nj=64,Ni=512",
                    help="concrete dim sizes enabling the VMEM budget "
                         "check (PC003) and the concrete vec model")
    ap.add_argument("--vmem-budget", type=int, default=None, metavar="BYTES",
                    help="VMEM budget for PC003 (default: "
                         "REPRO_VMEM_BUDGET_BYTES or ~16 MiB)")
    ap.add_argument("--vec", action="store_true",
                    help="also run the vectorization analyzer (PV "
                         "diagnostic family, repro.core.vecscan)")
    ap.add_argument("--apply-layout", choices=("off", "auto", "force"),
                    default="off", metavar="MODE",
                    help="run plans through the LayoutApply pass "
                         "(repro.core.layoutapply) before linting: "
                         "off (default), auto, or force")
    ap.add_argument("--update-vec-baseline", action="store_true",
                    help="regenerate tests/goldens/vec_lint_baseline.json "
                         "from the golden corpus (honors --apply-layout "
                         "and --sizes) instead of linting targets")
    ap.add_argument("--format", choices=("text", "json"), default="text",
                    help="output format: human-readable text (default) "
                         "or one JSON object per analyzed plan")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero on warnings too")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="print findings only, no per-target OK lines "
                         "(text format)")
    args = ap.parse_args(argv)
    sizes = parse_sizes(args.sizes)

    if args.update_vec_baseline:
        return update_vec_baseline(sizes, args.vmem_budget,
                                   apply_mode=args.apply_layout)

    targets: list[str] = []
    for t in args.targets or [str(GOLDEN_DIR)]:
        path = pathlib.Path(t)
        if path.is_dir():
            targets.extend(sorted(str(p) for p in path.glob("*.json")))
        else:
            targets.append(t)
    if not args.targets:
        from repro.core.programs import ALL_PROGRAMS
        targets.extend(sorted(ALL_PROGRAMS))

    n_err = n_warn = 0
    for target in targets:
        label, diags, summary = lint_target(target, sizes,
                                            args.vmem_budget, vec=args.vec,
                                            apply_mode=args.apply_layout)
        errs = [d for d in diags if d.severity == "error"]
        warns = [d for d in diags if d.severity != "error"]
        n_err += len(errs)
        n_warn += len(warns)
        if args.format == "json":
            record = {
                "target": label,
                "errors": len(errs),
                "warnings": len(warns),
                "diagnostics": [dataclasses.asdict(d) for d in diags],
            }
            if summary is not None:
                record["vec"] = summary
            print(json.dumps(record, sort_keys=True))
            continue
        if not diags:
            if not args.quiet:
                print(f"  {label}: OK")
            continue
        print(f"  {label}: {len(errs)} error(s), {len(warns)} warning(s)")
        for d in diags:
            print(f"    {d}")
    if args.format != "json":
        print(f"plan_lint: {len(targets)} target(s), {n_err} error(s), "
              f"{n_warn} warning(s)")
    if n_err or (args.strict and n_warn):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
