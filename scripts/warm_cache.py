#!/usr/bin/env python
"""Pre-plan every program in ``repro.core.programs`` into an on-disk
AOT plan cache (and, with ``--goldens``, regenerate the golden-plan
corpus under ``tests/goldens/plans/``).

Run from the repo root::

    PYTHONPATH=src python scripts/warm_cache.py --cache-dir .plan_cache
    PYTHONPATH=src python scripts/warm_cache.py --goldens

A warmed cache directory lets any later process compile these programs
on the Pallas backend without ever invoking the analysis pipeline:
``compile_program(prog, backend="pallas", plan_cache_dir=...)`` loads
the serialized :class:`~repro.core.plan.KernelPlan`, re-validates it,
and builds the interpreter directly (see docs/BACKENDS.md, "AOT plan
cache").  The golden corpus is the same serialized form checked into
the repo — ``tests/test_plan.py`` re-plans every program on every run
and diffs against it, so planner drift shows up as a reviewable
golden-file change, regenerated only through this script.
"""
from __future__ import annotations

import argparse
import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

from repro.core import plan_pallas  # noqa: E402
from repro.core.dataflow import build_dataflow  # noqa: E402
from repro.core.fusion import fuse_inest_dag  # noqa: E402
from repro.core.infer import infer  # noqa: E402
from repro.core.plancache import PlanCache, program_plan_key  # noqa: E402
from repro.core.plancheck import check_plan, has_errors  # noqa: E402
from repro.core.programs import ALL_PROGRAMS  # noqa: E402
from repro.core.reuse import analyze_storage  # noqa: E402

GOLDEN_DIR = ROOT / "tests" / "goldens" / "plans"


def plan_program(build):
    """Run the pure analysis pipeline (no execution) for one builder."""
    program = build()
    idag = infer(program)
    storage = analyze_storage(fuse_inest_dag(build_dataflow(idag)))
    return program, plan_pallas(storage, idag)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Pre-plan every repro.core.programs entry into an "
                    "on-disk AOT plan cache / the golden-plan corpus.")
    ap.add_argument("--cache-dir", default=None,
                    help="plan-cache directory to warm (created if "
                         "missing); omit to skip cache warming")
    ap.add_argument("--goldens", action="store_true",
                    help=f"rewrite the golden corpus under "
                         f"{GOLDEN_DIR.relative_to(ROOT)}")
    args = ap.parse_args(argv)
    if args.cache_dir is None and not args.goldens:
        ap.error("nothing to do: pass --cache-dir and/or --goldens")

    cache = PlanCache(args.cache_dir) if args.cache_dir else None
    if args.goldens:
        GOLDEN_DIR.mkdir(parents=True, exist_ok=True)
    refused = 0
    for name, build in sorted(ALL_PROGRAMS.items()):
        program, kplan = plan_program(build)
        what = []
        # gate every persisted plan on the static analyzer: a poisoned
        # cache entry or golden propagates to every warm process
        diags = check_plan(kplan)
        if has_errors(diags):
            refused += 1
            print(f"  {name:24s} REFUSED: "
                  f"{sum(d.severity == 'error' for d in diags)} "
                  f"error-severity finding(s)")
            for d in diags:
                print(f"      {d}")
            continue
        for d in diags:
            print(f"      {d}")
        if cache is not None:
            stored = cache.put(program_plan_key(program), kplan)
            what.append("cached" if stored else "NOT SERIALIZABLE")
        if args.goldens:
            path = GOLDEN_DIR / f"{name}.json"
            path.write_text(
                json.dumps(kplan.to_dict(), indent=1, sort_keys=True) + "\n")
            what.append("golden")
        print(f"  {name:24s} {len(kplan.calls)} call(s)  [{', '.join(what)}]")
    if cache is not None:
        print(f"warmed {args.cache_dir}: {len(cache)} entr(y/ies)")
    if args.goldens:
        print(f"wrote goldens to {GOLDEN_DIR.relative_to(ROOT)}")
    if refused:
        print(f"refused to persist {refused} plan(s) with error-severity "
              f"findings (see scripts/plan_lint.py)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
