#!/usr/bin/env bash
# Documentation guardrails, run as part of the tier-1 flow (invoked by
# tests/test_docs.py, which both the canonical tier-1 pytest command
# and scripts/test_fast.sh execute):
#
#   1. every public (non-underscore) module-level function/class in
#      repro.core.engine must carry a docstring — the engine is the
#      public API surface documented in docs/BACKENDS.md;
#   2. every ```python code block in docs/*.md must still parse, and
#      its import statements must still resolve — so the docs cannot
#      silently rot as modules move.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'PY'
import ast
import pathlib
import sys

failures: list[str] = []

# ---- 1. public symbols in core/engine.py need docstrings ------------------
engine = pathlib.Path("src/repro/core/engine.py")
tree = ast.parse(engine.read_text())
for node in tree.body:
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
        continue
    if node.name.startswith("_"):
        continue
    if ast.get_docstring(node) is None:
        failures.append(f"{engine}:{node.lineno}: public symbol "
                        f"{node.name!r} lacks a docstring")

# ---- 2. python code blocks in docs/*.md must stay importable --------------
def blocks(text: str):
    lines = text.splitlines()
    cur: list[str] | None = None
    start = 0
    for n, line in enumerate(lines, 1):
        s = line.strip()
        if cur is None and s.startswith("```python"):
            cur, start = [], n + 1
        elif cur is not None and s.startswith("```"):
            yield start, "\n".join(cur)
            cur = None
        elif cur is not None:
            cur.append(line)

for doc in sorted(pathlib.Path("docs").glob("*.md")):
    for lineno, code in blocks(doc.read_text()):
        try:
            block = ast.parse(code)
        except SyntaxError as e:
            failures.append(f"{doc}:{lineno}: code block does not parse: {e}")
            continue
        imports = [n for n in block.body
                   if isinstance(n, (ast.Import, ast.ImportFrom))]
        for imp in imports:
            src = ast.unparse(imp)
            try:
                exec(compile(ast.Module([imp], []), str(doc), "exec"), {})
            except Exception as e:
                failures.append(
                    f"{doc}:{lineno + imp.lineno - 1}: {src!r} failed: {e}")

if failures:
    print("check_docs: FAIL")
    for f in failures:
        print("  " + f)
    sys.exit(1)
print("check_docs: OK (engine docstrings + docs/*.md code blocks)")
PY
