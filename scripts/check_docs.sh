#!/usr/bin/env bash
# Documentation guardrails, run as part of the tier-1 flow (invoked by
# tests/test_docs.py, which both the canonical tier-1 pytest command
# and scripts/test_fast.sh execute):
#
#   1. every public (non-underscore) module-level function/class in
#      repro.core.engine must carry a docstring — the engine is the
#      public API surface documented in docs/BACKENDS.md;
#   2. every ```python code block in docs/*.md must still parse, and
#      its import statements must still resolve — so the docs cannot
#      silently rot as modules move.
#   3. every `raise PallasUnsupported` site in plan.py (the validate
#      pass that owns them all) — and any stray site reintroduced into
#      codegen_pallas.py — must carry a `# doc-row: <key>` marker whose
#      key appears in the docs/BACKENDS.md restriction table — the live
#      table cannot drift from the actual raise sites;
#   4. every public (non-underscore) module-level dataclass and
#      function in repro.core.plan must carry a docstring — the
#      KernelPlan IR is the planner/interpreter contract.
#   5. every PC<nnn> diagnostic code emitted in repro.core.plancheck
#      must have a row in the docs/ARCHITECTURE.md diagnostic table,
#      and every table row must correspond to a code the analyzer can
#      actually emit — the live code table cannot drift either way.
#   6. every interpreter in the plan-interpreter registry must have a
#      row in the docs/BACKENDS.md "Interpreter registry" table, and
#      every table row must name a registered interpreter — new
#      registrations cannot land undocumented, and stale rows cannot
#      outlive their interpreter.
#   7. every PV<nnn> diagnostic code emitted in repro.core.vecscan
#      must have a row in the docs/ARCHITECTURE.md vectorization
#      table, and every table row must correspond to a code the
#      analyzer can actually emit — same bidirectional contract as
#      the PC table (guard 5).
#   8. every hint kind the LayoutApply pass handles
#      (repro.core.layoutapply.HANDLED_HINTS) must have a row in the
#      docs/ARCHITECTURE.md "Layout transformation" hint table, and
#      every table row must name a handled kind — the pass and its
#      docs cannot drift either way.
#   9. every backend PlanServe accepts (repro.serve.plans.VMAP_SAFE)
#      must exist, and every VMAP_SAFE member and registered
#      interpreter must be classified in the docs/BACKENDS.md "Plan
#      serving and vmap safety" section — a new interpreter cannot be
#      registered without an explicit serving-safety call.
set -euo pipefail
cd "$(dirname "$0")/.."

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python - <<'PY'
import ast
import pathlib
import sys

failures: list[str] = []

# ---- 1. public symbols in core/engine.py need docstrings ------------------
engine = pathlib.Path("src/repro/core/engine.py")
tree = ast.parse(engine.read_text())
for node in tree.body:
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
        continue
    if node.name.startswith("_"):
        continue
    if ast.get_docstring(node) is None:
        failures.append(f"{engine}:{node.lineno}: public symbol "
                        f"{node.name!r} lacks a docstring")

# ---- 2. python code blocks in docs/*.md must stay importable --------------
def blocks(text: str):
    lines = text.splitlines()
    cur: list[str] | None = None
    start = 0
    for n, line in enumerate(lines, 1):
        s = line.strip()
        if cur is None and s.startswith("```python"):
            cur, start = [], n + 1
        elif cur is not None and s.startswith("```"):
            yield start, "\n".join(cur)
            cur = None
        elif cur is not None:
            cur.append(line)

for doc in sorted(pathlib.Path("docs").glob("*.md")):
    for lineno, code in blocks(doc.read_text()):
        try:
            block = ast.parse(code)
        except SyntaxError as e:
            failures.append(f"{doc}:{lineno}: code block does not parse: {e}")
            continue
        imports = [n for n in block.body
                   if isinstance(n, (ast.Import, ast.ImportFrom))]
        for imp in imports:
            src = ast.unparse(imp)
            try:
                exec(compile(ast.Module([imp], []), str(doc), "exec"), {})
            except Exception as e:
                failures.append(
                    f"{doc}:{lineno + imp.lineno - 1}: {src!r} failed: {e}")

# ---- 3. PallasUnsupported raise sites must map to BACKENDS.md rows --------
backends = pathlib.Path("docs/BACKENDS.md").read_text()
start = backends.find("## Remaining `PallasUnsupported` cases")
end = backends.find("Formerly restricted", start)
table = backends[start:end if end != -1 else None].lower()
if start == -1 or "| Restriction |" not in backends[start:]:
    failures.append("docs/BACKENDS.md: restriction table section missing")
    table = ""


class _Raises(ast.NodeVisitor):
    def __init__(self):
        self.sites: list[int] = []

    def visit_Raise(self, node):
        exc = node.exc
        name = ""
        if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
            name = exc.func.id
        elif isinstance(exc, ast.Name):
            name = exc.id
        if name == "PallasUnsupported":
            self.sites.append(node.lineno)
        self.generic_visit(node)


for mod in ("src/repro/core/plan.py", "src/repro/core/codegen_pallas.py"):
    mod_path = pathlib.Path(mod)
    mod_src = mod_path.read_text()
    mod_lines = mod_src.splitlines()
    viz = _Raises()
    viz.visit(ast.parse(mod_src))
    for lineno in viz.sites:
        key = None
        # the marker sits on the raise line or the line directly above it
        for cand in (mod_lines[lineno - 1], mod_lines[lineno - 2]):
            if "# doc-row:" in cand:
                key = cand.split("# doc-row:", 1)[1].strip()
                break
        if key is None:
            failures.append(
                f"{mod_path}:{lineno}: raise PallasUnsupported site lacks a "
                f"'# doc-row: <key>' marker tying it to the docs/BACKENDS.md "
                f"restriction table")
        elif key.lower() not in table:
            failures.append(
                f"{mod_path}:{lineno}: doc-row key {key!r} has no matching "
                f"row in the docs/BACKENDS.md restriction table")

# ---- 4. public plan.py dataclasses/functions need docstrings --------------
plan_path = pathlib.Path("src/repro/core/plan.py")
plan_tree = ast.parse(plan_path.read_text())
for node in plan_tree.body:
    if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.ClassDef)):
        continue
    if node.name.startswith("_"):
        continue
    if ast.get_docstring(node) is None:
        failures.append(f"{plan_path}:{node.lineno}: public plan-IR symbol "
                        f"{node.name!r} lacks a docstring")
    if isinstance(node, ast.ClassDef):
        for sub in node.body:
            if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and not sub.name.startswith("_") \
                    and ast.get_docstring(sub) is None:
                failures.append(
                    f"{plan_path}:{sub.lineno}: public plan-IR method "
                    f"{node.name}.{sub.name} lacks a docstring")

# ---- 5. plancheck PC codes <-> ARCHITECTURE.md diagnostic table -----------
import re

pc_path = pathlib.Path("src/repro/core/plancheck.py")
emitted = set(re.findall(r'"(PC\d{3})"', pc_path.read_text()))
arch = pathlib.Path("docs/ARCHITECTURE.md").read_text()
documented = set(re.findall(r"^\|\s*`?(PC\d{3})`?\s*\|", arch, re.M))
if not documented:
    failures.append("docs/ARCHITECTURE.md: diagnostic-code table missing "
                    "(no | PCnnn | rows found)")
for code in sorted(emitted - documented):
    failures.append(f"{pc_path}: diagnostic {code} is emitted but has no "
                    f"row in the docs/ARCHITECTURE.md diagnostic table")
for code in sorted(documented - emitted):
    failures.append(f"docs/ARCHITECTURE.md: diagnostic {code} is documented "
                    f"but {pc_path} never emits it")

# ---- 5b. vecscan PV codes <-> ARCHITECTURE.md vectorization table ---------
pv_path = pathlib.Path("src/repro/core/vecscan.py")
pv_emitted = set(re.findall(r"(PV\d{3})", pv_path.read_text()))
pv_documented = set(re.findall(r"^\|\s*`?(PV\d{3})`?\s*\|", arch, re.M))
if not pv_documented:
    failures.append("docs/ARCHITECTURE.md: vectorization diagnostic table "
                    "missing (no | PVnnn | rows found)")
for code in sorted(pv_emitted - pv_documented):
    failures.append(f"{pv_path}: diagnostic {code} is emitted but has no "
                    f"row in the docs/ARCHITECTURE.md vectorization table")
for code in sorted(pv_documented - pv_emitted):
    failures.append(f"docs/ARCHITECTURE.md: diagnostic {code} is documented "
                    f"but {pv_path} never emits it")

# ---- 6. interpreter registry <-> BACKENDS.md registry table ---------------
from repro.core.interpreters import registered_interpreters

registered = set(registered_interpreters())
reg_start = backends.find("## Interpreter registry")
reg_end = backends.find("\n## ", reg_start + 1)
reg_section = backends[reg_start:reg_end if reg_end != -1 else None]
rows = set(re.findall(r"^\|\s*`([^`|]+)`\s*\|", reg_section, re.M))
if reg_start == -1 or not rows:
    failures.append("docs/BACKENDS.md: 'Interpreter registry' table missing "
                    "(no | `name` | rows found)")
for name in sorted(registered - rows):
    failures.append(f"interpreter {name!r} is registered but has no row in "
                    f"the docs/BACKENDS.md interpreter-registry table")
for name in sorted(rows - registered):
    failures.append(f"docs/BACKENDS.md: interpreter-registry row {name!r} "
                    f"names no registered interpreter")

# ---- 7. LayoutApply HANDLED_HINTS <-> ARCHITECTURE.md hint table ----------
from repro.core.layoutapply import HANDLED_HINTS

lt_start = arch.find("## Layout transformation")
lt_end = arch.find("\n## ", lt_start + 1)
lt_section = arch[lt_start:lt_end if lt_end != -1 else None]
hint_rows = set(re.findall(r"^\|\s*`([a-z_]+)`\s*\|", lt_section, re.M))
if lt_start == -1 or not hint_rows:
    failures.append("docs/ARCHITECTURE.md: 'Layout transformation' hint "
                    "table missing (no | `kind` | rows found)")
for kind in sorted(set(HANDLED_HINTS) - hint_rows):
    failures.append(
        f"layoutapply: hint kind {kind!r} is handled "
        f"(repro.core.layoutapply.HANDLED_HINTS) but has no row in the "
        f"docs/ARCHITECTURE.md layout-transformation hint table")
for kind in sorted(hint_rows - set(HANDLED_HINTS)):
    failures.append(
        f"docs/ARCHITECTURE.md: layout-transformation hint row {kind!r} "
        f"names no handled hint kind "
        f"(repro.core.layoutapply.HANDLED_HINTS)")

# ---- 9. PlanServe VMAP_SAFE <-> BACKENDS.md serving classification --------
# Every backend PlanServe accepts must exist (the legacy jax emitter or
# a registered interpreter) and be named in the docs' "Plan serving and
# vmap safety" section; every *registered* interpreter must be
# classified there too (named as vmap-safe or explicitly not), so a new
# registration cannot land without a serving-safety call.
from repro.serve.plans import VMAP_SAFE

vs_start = backends.find("## Plan serving and vmap safety")
vs_end = backends.find("\n## ", vs_start + 1)
vs_section = backends[vs_start:vs_end if vs_end != -1 else None]
if vs_start == -1:
    failures.append("docs/BACKENDS.md: 'Plan serving and vmap safety' "
                    "section missing")
    vs_section = ""
for name in sorted(VMAP_SAFE - ({"jax"} | registered)):
    failures.append(
        f"repro.serve.plans.VMAP_SAFE names {name!r}, which is neither "
        f"the legacy jax emitter nor a registered interpreter")
for name in sorted(VMAP_SAFE | registered):
    if f"`{name}`" not in vs_section:
        failures.append(
            f"docs/BACKENDS.md: backend {name!r} is not classified in the "
            f"'Plan serving and vmap safety' section (every VMAP_SAFE "
            f"member and every registered interpreter needs a "
            f"vmap-safety call there)")

if failures:
    print("check_docs: FAIL")
    for f in failures:
        print("  " + f)
    sys.exit(1)
print("check_docs: OK (engine docstrings + docs/*.md code blocks + "
      "PallasUnsupported restriction table + plan-IR docstrings + "
      "PlanCheck diagnostic table + VecScan diagnostic table + "
      "interpreter-registry table + LayoutApply hint table + "
      "PlanServe vmap-safety classification)")
PY
