#!/usr/bin/env bash
# Static gates, run by scripts/test_fast.sh ahead of the suite:
#
#   1. ruff over src/repro/core (scope + rule selection in ruff.toml)
#      — skipped with a notice when ruff isn't installed, so the gate
#      degrades rather than failing on a missing dev dep (the container
#      image may not carry requirements-dev.txt);
#   2. scripts/plan_lint.py over the golden-plan corpus — every
#      checked-in plan must pass the KernelPlan static analyzer
#      (repro.core.plancheck) with zero error-severity findings.
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "lint.sh: ruff not installed; skipping the ruff gate"
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/plan_lint.py tests/goldens/plans -q
