#!/usr/bin/env bash
# Static gates, run by scripts/test_fast.sh ahead of the suite:
#
#   1. ruff over src/repro/core (scope + rule selection in ruff.toml)
#      — skipped with a notice when ruff isn't installed, so the gate
#      degrades rather than failing on a missing dev dep (the container
#      image may not carry requirements-dev.txt);
#   2. scripts/plan_lint.py over the golden-plan corpus — every
#      checked-in plan must pass the KernelPlan static analyzer
#      (repro.core.plancheck) with zero error-severity findings;
#   3. the same corpus through `plan_lint.py --vec --apply-layout
#      force --format json` — every golden is first run through the
#      LayoutApply pass (repro.core.layoutapply) so the analyzers
#      (plancheck + the repro.core.vecscan vectorization analyzer)
#      see the *transformed* plan — gated on error-severity
#      regressions against the checked-in baseline
#      tests/goldens/vec_lint_baseline.json (regenerate with
#      `plan_lint.py --update-vec-baseline --apply-layout force`).
set -euo pipefail
cd "$(dirname "$0")/.."

if command -v ruff >/dev/null 2>&1; then
    ruff check .
else
    echo "lint.sh: ruff not installed; skipping the ruff gate"
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/plan_lint.py tests/goldens/plans -q

vec_json="$(mktemp)"
trap 'rm -f "$vec_json"' EXIT
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python scripts/plan_lint.py tests/goldens/plans --vec \
    --apply-layout force --format json \
    > "$vec_json"
python - "$vec_json" <<'PY'
import json, pathlib, sys

baseline = json.loads(pathlib.Path(
    "tests/goldens/vec_lint_baseline.json").read_text())["errors"]
bad = []
seen = set()
for line in pathlib.Path(sys.argv[1]).read_text().splitlines():
    r = json.loads(line)
    name = pathlib.Path(r["target"]).name
    seen.add(name)
    if r["errors"] > baseline.get(name, 0):
        bad.append(f"{name}: {r['errors']} error(s) vs baseline "
                   f"{baseline.get(name, 0)}")
missing = sorted(set(baseline) - seen)
if missing:
    bad.append(f"baseline plans never linted: {', '.join(missing)}")
if bad:
    print("lint.sh: vec-lint regression against "
          "tests/goldens/vec_lint_baseline.json:")
    for b in bad:
        print(f"  {b}")
    sys.exit(1)
print(f"vec lint: {len(seen)} golden plan(s), no error regression")
PY
