"""Insert roofline tables + dry-run records into EXPERIMENTS.md."""
import sys
sys.path.insert(0, "src")
from repro.roofline.report import load_records, roofline_table, dryrun_table

recs = load_records("reports/dryrun")
blob = (
    "### Single-pod (16x16) roofline — all 40 cells\n\n"
    + roofline_table(recs, "16x16")
    + "\n\n### Multi-pod (2x16x16) roofline\n\n"
    + roofline_table(recs, "2x16x16")
    + "\n\n### Dry-run memory/cost records (per device)\n\n"
    + dryrun_table(recs)
)
s = open("EXPERIMENTS.md").read()
marker = "<!-- ROOFLINE_TABLES -->"
assert marker in s
pre = s.split(marker)[0]
post = s.split(marker)[1]
# drop any previously inserted tables between the markers
if "<!-- /ROOFLINE_TABLES -->" in post:
    post = post.split("<!-- /ROOFLINE_TABLES -->", 1)[1]
s = pre + marker + "\n\n" + blob + "\n\n<!-- /ROOFLINE_TABLES -->" + post
open("EXPERIMENTS.md", "w").write(s)
print("tables inserted:", len(recs), "records")
